//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds with **zero network access** (this environment has
//! no crates.io mirror — see the repo README's build matrix).
//!
//! Covered surface (everything `psm` uses):
//!
//! * [`Error`] / [`Result`] — an error is a context chain of messages;
//!   `{e}` prints the outermost message, `{e:#}` the full chain joined
//!   with `": "`, `{e:?}` the anyhow-style "Caused by" listing.
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`
//!   (the source chain is captured eagerly as strings).
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//! * [`Error::downcast_ref`] — the original error value converted via
//!   `?` (or [`Error::new`]) is kept as a typed payload alongside the
//!   string chain, and survives any number of `.context(..)` wraps.
//!   This is what lets callers recover a typed error class (e.g.
//!   `psm`'s `PsmError` taxonomy) from an `anyhow::Error`.
//!
//! Deliberately NOT covered: backtraces, `downcast` by value /
//! `downcast_mut`, `Error::chain` of typed sources (the source chain is
//! captured eagerly as strings; only the outermost concrete error is
//! kept as a payload).

use std::any::Any;
use std::fmt::{self, Display};

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost (most recently
/// added) context, later entries are causes. When the error was built
/// from a concrete `std::error::Error` value, that value rides along as
/// a typed payload for [`Error::downcast_ref`].
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Wrap a concrete error, preserving it for downcasting (the
    /// `anyhow::Error::new` entry point).
    pub fn new<E>(err: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        err.into()
    }

    /// Prepend a context message (what `.context(..)` does). The typed
    /// payload, if any, is preserved.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// Outermost-to-innermost context/cause messages.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// Borrow the concrete error this `Error` was converted from, if it
    /// was built from a value of type `E` (directly via `?`/[`Error::new`];
    /// `.context(..)` wraps do not erase it).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.payload.as_deref()?.downcast_ref::<E>()
    }

    /// Whether the payload is a value of type `E`.
    pub fn is<E: 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like real anyhow, `Error` intentionally does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent (no overlap with the reflexive `From<Error> for Error`).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, payload: Some(Box::new(err)) }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — build an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {args}")` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_message(), "gone");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn downcast_ref_survives_context() {
        let e: Error = io_err().into();
        let e = e.context("outer").context("outermost");
        let io = e.downcast_ref::<std::io::Error>().expect("payload kept");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.is::<std::io::Error>());
        assert!(!e.is::<std::fmt::Error>());
        // Message-built errors carry no payload.
        let plain = anyhow!("no payload");
        assert!(plain.downcast_ref::<std::io::Error>().is_none());
        // Error::new is the explicit wrapping entry point.
        let wrapped = Error::new(io_err());
        assert!(wrapped.is::<std::io::Error>());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3u8).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn result_with_context_chains() {
        let r: Result<(), Error> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
        assert_eq!(e.chain().count(), 2);
    }
}
